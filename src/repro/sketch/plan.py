"""ExecutionPlan: config-driven dispatch for the sketch aggregation phase.

The paper's engine is one pipeline behind one interface; the repro grew five
entry points (scatter update, lane-pipelined update, device-sharded update,
datapath tap, and the two Pallas wrappers) with divergent defaults.  An
``ExecutionPlan`` names the full execution space instead:

  backend    "jnp"              XLA scatter-max reference (paper Algorithm 1)
             "pallas"           fused Pallas kernel, registers resident in
                                VMEM for the whole sweep (small-p sketches)
             "pallas_pipelined" k fused Pallas pipelines + max-fold kernel
                                (paper Fig. 3 built from kernels)
  placement  "local"            one device
             "mesh"             items sharded over ``data_axes`` of ``mesh``;
                                partial sketches fold with one all-reduce-max
             "sharded"          the BANK'S ROW AXIS sharded over ``data_axes``
                                of ``mesh`` (DESIGN.md §16): every device owns
                                a block of tenant rows, the keyed stream is
                                re-based into block-local coordinates and the
                                §9 drop rule discards foreign keys — routing
                                without a collective.  Surfaces with no row
                                axis (single-sketch updates, count-min ingest)
                                degrade to the mesh stream-sharding rule,
                                which is bit-identical by the same lattice
                                laws.
  pipelines  k sub-sketch lanes per device (paper Fig. 3); every backend
             produces registers bit-identical to the k=1 reference because
             max is associative/commutative/idempotent (DESIGN.md §6).
  estimator  phase-4 finalizer name ("original" | "ertl_improved" |
             "ertl_mle"), resolved against the estimator registry in
             repro/sketch/estimators.py (DESIGN.md §8).

Streams whose length does not divide ``pipelines`` (or the kernel tile) are
padded uniformly; padding is neutralized by rank-0 masking, never raising.

New backends register through :func:`register_backend` and new finalizers
through :func:`repro.sketch.estimators.register_estimator` — the seams
future PRs (sparse registers, compressed HLL representations, streaming
martingale estimators) plug into.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax

from repro.obs import metrics as obs_metrics
from repro.sketch.estimators import DEFAULT_ESTIMATOR, get_estimator

DEFAULT_PIPELINES = 8  # unified default (was 8 in core.sketch, 4 in kernels.ops)

PLACEMENTS = ("local", "mesh", "sharded")

# backend name -> fn(registers, flat_items, cfg, plan) -> registers
_BACKENDS: Dict[str, Callable] = {}

# backend name -> fn(bank_registers, keys, flat_items, cfg, plan) -> bank.
# Bank ingest paths register under the SAME names as their single-sketch
# counterparts, so one ExecutionPlan drives both `update_registers` and
# `update_many` (DESIGN.md §9).
_BANK_BACKENDS: Dict[str, Callable] = {}

# backend name -> fn(ring_registers, mask, cfg, plan) -> (B, m) registers.
# Windowed folds collapse the (W, B, m) ring of a WindowedBank into one
# scratch bank with a single masked max-reduce (DESIGN.md §11); they
# register under the same names as the other two axes so one ExecutionPlan
# drives ingest, bank ingest, and window folds alike.
_WINDOW_BACKENDS: Dict[str, Callable] = {}

# backend name -> fn(parts, cfg, plan) -> (B, m) registers.
# The read side of the incremental window decomposition (DESIGN.md §14):
# ``parts`` is a tiny (K, B, m) stack of already-folded window fragments
# (prefix-stack top, suffix accumulator, dirty head bucket) and the merge
# collapses it to one scratch bank.  Split out from the ring-fold axis so
# the O(1) incremental read path never pays W-sized dispatch; fold order
# is invisible because register max is an associative, commutative,
# idempotent lattice (DESIGN.md §6), so every entry is bit-identical to
# the full ring fold by construction.
_WINDOW_MERGE_BACKENDS: Dict[str, Callable] = {}


class CMBackend(NamedTuple):
    """The count-min backend pair: fused ingest + batched point query.

    ingest: fn(counters, keys, flat_items, cfg, plan) -> (B, d, w) counters
    query:  fn(counters, flat_items, cfg, plan) -> (B, n) uint32 counts
    """

    ingest: Callable
    query: Callable


# backend name -> CMBackend.  The count-min family (DESIGN.md §13)
# registers under the SAME names as the HLL axes, so one ExecutionPlan
# drives cardinality and heavy-hitter sketches alike.
_CM_BACKENDS: Dict[str, CMBackend] = {}

# backend name -> fn(ring_counters, mask, cfg, plan) -> (B, d, w) counters.
# The fourth registry axis: windowed count-min folds collapse the
# (W, B, d, w) counter ring with one masked SUM-reduce (the additive
# mirror of the window fold above).
_CM_WINDOW_BACKENDS: Dict[str, Callable] = {}


class SparseDedup(NamedTuple):
    """Canonical dedup of a (row, bucket, rank) triple stream (DESIGN.md §12).

    A sparse backend answers "what is each row's distinct bucket -> max-rank
    map" for the HybridBank compaction step, in one of two layouts (both
    enumerate every live row's buckets in ascending order, so the compacted
    COO pairs, promoted registers, and distinct counts derived from either
    are bit-identical):

    * **sorted stream** (``cells=None``): ``cell_s`` holds ``row*m + bucket``
      ids sorted ascending with padding at a trailing sentinel, ``rank_s``
      the co-sorted ranks, and ``survivor`` marks the last (max-rank) entry
      of each live cell run — the argsort form, cost O(n log n) in the
      stream length, which wins when the stream is small next to the bank.
    * **dense cells** (``cells`` set): ``cells`` is the (rows, m) int32
      max-rank map itself (0 = untouched bucket) and the stream fields are
      None — the scatter form (jnp segment-max or the sparse_scatter Pallas
      kernel), cost O(n + rows*m), which wins once the stream rivals the
      bank's cell count.

    ``distinct`` is always the (rows,) int32 per-row distinct-bucket count.
    """

    distinct: "jax.Array"
    cells: Optional["jax.Array"] = None
    cell_s: Optional["jax.Array"] = None
    rank_s: Optional["jax.Array"] = None
    survivor: Optional["jax.Array"] = None


# backend name -> fn(row, bucket, rank, rows, cfg, plan) -> SparseDedup.
# The HybridBank append-buffer compaction (DESIGN.md §12) dispatches its
# dedup through this axis; entries register under the SAME names as the
# other axes so one ExecutionPlan drives eager ingest, bank ingest, window
# folds, and sparse compaction alike.
_SPARSE_BACKENDS: Dict[str, Callable] = {}


def register_backend(name: str) -> Callable[[Callable], Callable]:
    """Decorator: register an aggregation backend under ``name``."""

    def deco(fn: Callable) -> Callable:
        if name in _BACKENDS:
            raise ValueError(f"backend {name!r} already registered")
        # every axis wraps at registration so per-backend dispatch counts
        # and wall time (DESIGN.md §15) cost one flag check when disabled;
        # short-circuits (empty streams) never reach the wrapper, so they
        # are never counted
        _BACKENDS[name] = obs_metrics.wrap_backend("update", name, fn)
        return fn

    return deco


def register_bank_backend(name: str) -> Callable[[Callable], Callable]:
    """Decorator: register a batched (SketchBank) ingest path under ``name``.

    The signature is fn(bank_registers, keys, flat_items, cfg, plan) ->
    (B, m) registers.  A backend without a bank entry still works for
    single-sketch plans; `update_many` raises a targeted error for it.
    """

    def deco(fn: Callable) -> Callable:
        if name in _BANK_BACKENDS:
            raise ValueError(f"bank backend {name!r} already registered")
        _BANK_BACKENDS[name] = obs_metrics.wrap_backend(
            "bank_update", name, fn
        )
        return fn

    return deco


def register_window_backend(name: str) -> Callable[[Callable], Callable]:
    """Decorator: register a windowed ring-fold path under ``name``.

    The signature is fn(ring_registers, mask, cfg, plan) -> (B, m)
    registers, where ``ring_registers`` is the (W, B, m) ring of a
    ``WindowedBank`` and ``mask`` is a (W,) bool selecting the live
    buckets.  Every entry must be bit-identical to the naive
    merge-each-bucket reference (tests/test_window.py).  A backend without
    a window entry still works for flat plans; ``estimate_window`` raises
    a targeted error for it.
    """

    def deco(fn: Callable) -> Callable:
        if name in _WINDOW_BACKENDS:
            raise ValueError(f"window backend {name!r} already registered")
        _WINDOW_BACKENDS[name] = obs_metrics.wrap_backend(
            "window_fold", name, fn
        )
        return fn

    return deco


def register_window_merge_backend(name: str) -> Callable[[Callable], Callable]:
    """Decorator: register an incremental window-merge path under ``name``.

    The signature is fn(parts, cfg, plan) -> (B, m) registers, where
    ``parts`` is a (K, B, m) stack of fold fragments — K is tiny and
    independent of W (the prefix-stack top, the suffix accumulator, and
    the dirty head bucket of the incremental decomposition, DESIGN.md
    §14).  Entries must be bit-identical to ``jnp.max(parts, axis=0)``.
    Unlike the other axes, a backend does not need its own entry to stay
    incremental-capable: ``get_window_merge_backend`` falls back to the
    jnp merge, which is exact for any fragment grouping by the
    max-lattice laws (DESIGN.md §6).
    """

    def deco(fn: Callable) -> Callable:
        if name in _WINDOW_MERGE_BACKENDS:
            raise ValueError(f"window merge backend {name!r} already registered")
        _WINDOW_MERGE_BACKENDS[name] = obs_metrics.wrap_backend(
            "window_merge", name, fn
        )
        return fn

    return deco


def register_cm_backend(name: str, ingest: Callable, query: Callable) -> CMBackend:
    """Register a count-min backend pair (fused ingest + point query).

    Unlike the single-function axes, a count-min backend is a PAIR —
    the scatter-add ingest and the gather-min query — so registration is
    a plain call rather than a decorator.  Signatures are documented on
    :class:`CMBackend`.  Every registered ingest must be bit-identical to
    the per-row reference loop (tests/test_countmin.py).
    """
    if name in _CM_BACKENDS:
        raise ValueError(f"cm backend {name!r} already registered")
    backend = CMBackend(
        obs_metrics.wrap_backend("cm_update", name, ingest),
        obs_metrics.wrap_backend("cm_query", name, query),
    )
    _CM_BACKENDS[name] = backend
    return backend


def register_cm_window_backend(name: str) -> Callable[[Callable], Callable]:
    """Decorator: register a windowed count-min ring-fold path under ``name``.

    The signature is fn(ring_counters, mask, cfg, plan) -> (B, d, w)
    counters, where ``ring_counters`` is the (W, B, d, w) ring of a
    ``WindowedCountMinBank`` and ``mask`` is a (W,) bool selecting the
    live buckets.  Every entry must be bit-identical to summing the live
    buckets one by one (tests/test_countmin.py).
    """

    def deco(fn: Callable) -> Callable:
        if name in _CM_WINDOW_BACKENDS:
            raise ValueError(f"cm window backend {name!r} already registered")
        _CM_WINDOW_BACKENDS[name] = obs_metrics.wrap_backend(
            "cm_window_fold", name, fn
        )
        return fn

    return deco


def register_sparse_backend(name: str) -> Callable[[Callable], Callable]:
    """Decorator: register a HybridBank dedup/compaction path under ``name``.

    The signature is fn(row, bucket, rank, rows, cfg, plan) ->
    :class:`SparseDedup`, where the int32 triple arrays carry the combined
    live-pair + append-buffer stream (entries with ``row`` outside
    [0, rows) are padding and must not survive).  Every entry must produce
    compacted pairs, promoted registers, and distinct counts bit-identical
    to the jnp reference (tests/test_sparse.py, tests/test_differential.py).
    """

    def deco(fn: Callable) -> Callable:
        if name in _SPARSE_BACKENDS:
            raise ValueError(f"sparse backend {name!r} already registered")
        _SPARSE_BACKENDS[name] = obs_metrics.wrap_backend(
            "sparse_dedup", name, fn
        )
        return fn

    return deco


def get_backend(name: str) -> Callable:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {sorted(_BACKENDS)}"
        ) from None


def get_bank_backend(name: str) -> Callable:
    try:
        return _BANK_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"backend {name!r} has no bank ingest path; bank-capable: "
            f"{sorted(_BANK_BACKENDS)}"
        ) from None


def get_window_backend(name: str) -> Callable:
    try:
        return _WINDOW_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"backend {name!r} has no window fold path; window-capable: "
            f"{sorted(_WINDOW_BACKENDS)}"
        ) from None


def get_window_merge_backend(name: str) -> Callable:
    """The incremental merge entry for ``name``, or the jnp fallback.

    This axis never raises for an unregistered name: fold fragments merge
    exactly under the reference jnp max-reduce whatever backend produced
    them, so a plan whose backend only registered a ring fold still gets
    the O(1) incremental read path (mirrors the sparse-dedup fallback).
    """
    fn = _WINDOW_MERGE_BACKENDS.get(name)
    if fn is not None:
        return fn
    try:
        return _WINDOW_MERGE_BACKENDS["jnp"]
    except KeyError:  # pragma: no cover - backends.py always registers jnp
        raise ValueError("no window merge backends registered") from None


def get_cm_backend(name: str) -> CMBackend:
    try:
        return _CM_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"backend {name!r} has no count-min path; cm-capable: "
            f"{sorted(_CM_BACKENDS)}"
        ) from None


def get_cm_window_backend(name: str) -> Callable:
    try:
        return _CM_WINDOW_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"backend {name!r} has no count-min window fold path; "
            f"cm-window-capable: {sorted(_CM_WINDOW_BACKENDS)}"
        ) from None


def get_sparse_backend(name: str) -> Callable:
    try:
        return _SPARSE_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"backend {name!r} has no sparse dedup path; sparse-capable: "
            f"{sorted(_SPARSE_BACKENDS)}"
        ) from None


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def available_bank_backends() -> Tuple[str, ...]:
    return tuple(sorted(_BANK_BACKENDS))


def available_window_backends() -> Tuple[str, ...]:
    return tuple(sorted(_WINDOW_BACKENDS))


def available_window_merge_backends() -> Tuple[str, ...]:
    return tuple(sorted(_WINDOW_MERGE_BACKENDS))


def available_cm_backends() -> Tuple[str, ...]:
    return tuple(sorted(_CM_BACKENDS))


def available_cm_window_backends() -> Tuple[str, ...]:
    return tuple(sorted(_CM_WINDOW_BACKENDS))


def available_sparse_backends() -> Tuple[str, ...]:
    return tuple(sorted(_SPARSE_BACKENDS))


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Where and how one ``update()`` call runs.  Hashable (jit-static)."""

    backend: str = "jnp"
    placement: str = "local"
    pipelines: int = DEFAULT_PIPELINES
    mesh: Optional[jax.sharding.Mesh] = None
    data_axes: Tuple[str, ...] = ("data",)
    # Pallas interpret mode: None = auto (interpret off-TPU, compiled on TPU)
    interpret: Optional[bool] = None
    # phase-4 finalizer, resolved against repro.sketch.estimators'
    # registry ("original" | "ertl_improved" | "ertl_mle" | plugins)
    estimator: str = DEFAULT_ESTIMATOR
    # storage hint for hybrid carriers (DESIGN.md §12): rows of a
    # HybridBank built under this plan promote from the sparse COO layout
    # to dense registers once their distinct-bucket count exceeds this.
    # None defers to the carrier default (m // 4); the carrier re-validates
    # against its config (must stay <= m // 2 for the LC-regime guarantee).
    sparse_threshold: Optional[int] = None

    def __post_init__(self):
        if self.placement not in PLACEMENTS:
            raise ValueError(
                f"placement must be one of {PLACEMENTS}, got {self.placement!r}"
            )
        if self.pipelines < 1:
            raise ValueError(f"pipelines must be >= 1, got {self.pipelines}")
        if self.sparse_threshold is not None and self.sparse_threshold < 1:
            raise ValueError(
                f"sparse_threshold must be >= 1, got {self.sparse_threshold}"
            )
        if self.placement in ("mesh", "sharded") and self.mesh is None:
            raise ValueError(f"placement={self.placement!r} requires a mesh")
        object.__setattr__(self, "data_axes", tuple(self.data_axes))

    def validate(self) -> "ExecutionPlan":
        """Check backend + estimator exist (deferred so plans build early)."""
        get_backend(self.backend)
        get_estimator(self.estimator)
        if self.placement in ("mesh", "sharded"):
            missing = set(self.data_axes) - set(self.mesh.axis_names)
            if missing:
                raise ValueError(
                    f"data_axes {sorted(missing)} not in mesh axes "
                    f"{self.mesh.axis_names}"
                )
        return self

    def with_mesh(self, mesh, data_axes=("data",)) -> "ExecutionPlan":
        return dataclasses.replace(
            self, placement="mesh", mesh=mesh, data_axes=tuple(data_axes)
        )

    def with_sharding(self, mesh, data_axes=("data",)) -> "ExecutionPlan":
        """Row-sharded placement (DESIGN.md §16): bank rows over ``mesh``."""
        return dataclasses.replace(
            self, placement="sharded", mesh=mesh, data_axes=tuple(data_axes)
        )


DEFAULT_PLAN = ExecutionPlan()


def reference_plan() -> ExecutionPlan:
    """The bit-exactness oracle: single-pipeline jnp scatter path."""
    return ExecutionPlan(backend="jnp", placement="local", pipelines=1)


def example_plans(mesh=None) -> Tuple[ExecutionPlan, ...]:
    """One representative plan per registered backend (x placements).

    The equivalence property tests iterate this, so any newly registered
    backend is automatically held to bit-identity with the reference.
    """
    plans = []
    for name in available_backends():
        for k in (1, 4, DEFAULT_PIPELINES):
            plans.append(ExecutionPlan(backend=name, pipelines=k))
        if mesh is not None:
            plans.append(
                ExecutionPlan(backend=name, pipelines=2).with_mesh(mesh)
            )
    return tuple(plans)
