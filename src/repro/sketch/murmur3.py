"""Murmur3 hash functions, vectorized in jnp, TPU-lowerable.

The paper hashes 32-bit input words with (a) Murmur3_x86_32 (the "32-bit
hash") and (b) the x64 variant producing a 64-bit value (the "64-bit hash"
used for p=16 / cardinalities beyond 1e8).  Both are reproduced bit-exactly:

* ``murmur3_32``  — Murmur3_x86_32 of a 4-byte little-endian key.
* ``murmur3_64``  — h1 of Murmur3_x64_128 of a 4-byte little-endian key,
  computed entirely in uint32 limb arithmetic (see core/u64.py) so the very
  same code path lowers on TPU and inside Pallas kernels.

Both take an int32/uint32 array of data items and are fully element-wise —
the TPU analogue of the paper's DSP-slice pipeline is that all lanes of the
VPU compute independent hashes every cycle.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.sketch import u64 as u64lib
from repro.sketch.u64 import U64

# --- Murmur3_x86_32 constants -------------------------------------------------
_C1_32 = np.uint32(0xCC9E2D51)
_C2_32 = np.uint32(0x1B873593)
_FMIX1_32 = np.uint32(0x85EBCA6B)
_FMIX2_32 = np.uint32(0xC2B2AE35)

# --- Murmur3_x64_128 constants ------------------------------------------------
_C1_64 = u64lib.from_py(0x87C37B91114253D5)
_C2_64 = u64lib.from_py(0x4CF5AD432745937F)
_M5 = u64lib.from_py(5)
_N1 = u64lib.from_py(0x52DCE729)
_N2 = u64lib.from_py(0x38495AB5)
_FMIX1_64 = u64lib.from_py(0xFF51AFD7ED558CCD)
_FMIX2_64 = u64lib.from_py(0xC4CEB9FE1A85EC53)


def _rotl32(x: jnp.ndarray, n: int) -> jnp.ndarray:
    return ((x << n) | (x >> (32 - n))).astype(jnp.uint32)


def fmix32(h: jnp.ndarray) -> jnp.ndarray:
    h = h ^ (h >> 16)
    h = (h * _FMIX1_32).astype(jnp.uint32)
    h = h ^ (h >> 13)
    h = (h * _FMIX2_32).astype(jnp.uint32)
    return h ^ (h >> 16)


def murmur3_32(keys: jnp.ndarray, seed: int = 0) -> jnp.ndarray:
    """Murmur3_x86_32 of each 32-bit item, treated as a 4-byte LE key."""
    k = keys.astype(jnp.uint32)
    h = jnp.full(k.shape, np.uint32(seed & 0xFFFFFFFF))

    # single 4-byte body block
    k = (k * _C1_32).astype(jnp.uint32)
    k = _rotl32(k, 15)
    k = (k * _C2_32).astype(jnp.uint32)
    h = h ^ k
    h = _rotl32(h, 13)
    h = (h * np.uint32(5) + np.uint32(0xE6546B64)).astype(jnp.uint32)

    # no tail; finalize with len=4
    h = h ^ np.uint32(4)
    return fmix32(h)


def fmix64(k: U64) -> U64:
    k = u64lib.xor(k, u64lib.shr(k, 33))
    k = u64lib.mul(k, _FMIX1_64)
    k = u64lib.xor(k, u64lib.shr(k, 33))
    k = u64lib.mul(k, _FMIX2_64)
    return u64lib.xor(k, u64lib.shr(k, 33))


def murmur3_64(keys: jnp.ndarray, seed: int = 0) -> U64:
    """h1 of Murmur3_x64_128 of each 32-bit item (4-byte LE key).

    A 4-byte key takes the tail path of the x64_128 algorithm:
      k1 = key; k1 *= c1; k1 = rotl(k1,31); k1 *= c2; h1 ^= k1
    then finalization with len=4.  Returns the full 64-bit h1 as a U64.
    """
    seed64 = u64lib.from_py(seed & 0xFFFFFFFFFFFFFFFF)
    k = keys.astype(jnp.uint32)
    zeros = jnp.zeros_like(k)
    h1 = U64(zeros + seed64.hi, zeros + seed64.lo)
    h2 = U64(zeros + seed64.hi, zeros + seed64.lo)

    # tail (len=4): k1 = uint64(key)
    k1 = u64lib.from_u32(k)
    k1 = u64lib.mul(k1, _C1_64)
    k1 = u64lib.rotl(k1, 31)
    k1 = u64lib.mul(k1, _C2_64)
    h1 = u64lib.xor(h1, k1)

    # finalization
    length = u64lib.from_py(4)
    h1 = u64lib.xor(h1, length)
    h2 = u64lib.xor(h2, length)
    h1 = u64lib.add(h1, h2)
    h2 = u64lib.add(h2, h1)
    h1 = fmix64(h1)
    h2 = fmix64(h2)
    h1 = u64lib.add(h1, h2)
    # (h2 += h1 would complete the 128-bit digest; h1 alone is our hash)
    return h1


def murmur3_64_py(key: int, seed: int = 0) -> int:
    """Pure-python oracle for murmur3_64 (test ground truth)."""
    mask = (1 << 64) - 1

    def rotl(x: int, n: int) -> int:
        return ((x << n) | (x >> (64 - n))) & mask

    def fmix(k: int) -> int:
        k ^= k >> 33
        k = (k * 0xFF51AFD7ED558CCD) & mask
        k ^= k >> 33
        k = (k * 0xC4CEB9FE1A85EC53) & mask
        k ^= k >> 33
        return k

    h1 = seed & mask
    h2 = seed & mask
    k1 = key & 0xFFFFFFFF
    k1 = (k1 * 0x87C37B91114253D5) & mask
    k1 = rotl(k1, 31)
    k1 = (k1 * 0x4CF5AD432745937F) & mask
    h1 ^= k1
    h1 = (h1 ^ 4) & mask
    h2 = (h2 ^ 4) & mask
    h1 = (h1 + h2) & mask
    h2 = (h2 + h1) & mask
    h1 = fmix(h1)
    h2 = fmix(h2)
    h1 = (h1 + h2) & mask
    return h1


def murmur3_32_py(key: int, seed: int = 0) -> int:
    """Pure-python oracle for murmur3_32 (test ground truth)."""
    mask = (1 << 32) - 1

    def rotl(x: int, n: int) -> int:
        return ((x << n) | (x >> (32 - n))) & mask

    h = seed & mask
    k = key & mask
    k = (k * 0xCC9E2D51) & mask
    k = rotl(k, 15)
    k = (k * 0x1B873593) & mask
    h ^= k
    h = rotl(h, 13)
    h = (h * 5 + 0xE6546B64) & mask
    h ^= 4
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & mask
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & mask
    h ^= h >> 16
    return h
