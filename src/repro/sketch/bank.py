"""SketchBank: a stacked (B, m) register bank with keyed batched ingestion.

PR 2 batch-parallelized finalization (``estimate_many`` over a (B, m) bank);
this module is its ingest-side counterpart.  A ``SketchBank`` carries B
sketches that share one static ``HLLConfig`` as a single frozen pytree —
(B, m) uint8 registers plus a (B, 2) uint32 limb counter per row — and
``update_many(bank, keys, items, plan)`` routes every item to its owning
row by key and applies the whole batch with ONE fused scatter-max, instead
of a python loop over sketches.  This is the paper's p-pipeline merge-fold
turned multi-tenant: the register bank is the only state that matters
(Ertl, arXiv:1702.01284), so the ingest path operates on whole banks the
same way memory-efficient FPGA sketch accelerators time-multiplex one
datapath over many flows (arXiv:2504.16896).

Key-routing contract (DESIGN.md §9):

* ``keys`` and ``items`` flatten to the same length; item i belongs to the
  sketch at row ``keys[i]``.
* valid keys are ``0 <= key < len(bank)``; out-of-range keys are DROPPED
  (their rank is routed to a discarded scatter cell), never clamped into a
  neighboring row — the ingest mirror of the histogram no-leak guard.
* every registered bank backend is bit-identical to the per-sketch loop
  ``for b: bank[b].update(items[keys == b])`` (tests/test_bank.py).

Per-row counters count *observations* per key exactly (dropped keys do not
count), so ``bank.row(b)`` round-trips to the same ``HyperLogLog`` the loop
would have produced, counter included.  Merge/serialization follow the
carrier's max-lattice and wire-format rules (DESIGN.md §6, §7) with a bank
header (magic ``RHLB``) over densely packed rows.
"""

from __future__ import annotations

import dataclasses
import functools
import struct
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.sketch import hll, u64 as u64lib
from repro.sketch.carrier import HyperLogLog
from repro.sketch.dispatch import mesh_fold, row_shard_apply, row_shard_fold
from repro.sketch.hll import HLLConfig
from repro.sketch.plan import DEFAULT_PLAN, ExecutionPlan, get_bank_backend

_BANK_HEADER = struct.Struct("<4sBBBBQI")  # magic, ver, p, H, flags, seed, B
_BANK_MAGIC = b"RHLB"
_BANK_VERSION = 1
_ROW_COUNT = struct.Struct("<Q")


def _counter_add_rows(limbs: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """(B, 2) uint32 limb pairs + (B,) non-negative counts, exact to 2^64."""
    add = u64lib.U64(jnp.zeros_like(counts, jnp.uint32), counts.astype(jnp.uint32))
    s = u64lib.add(u64lib.U64(limbs[:, 0], limbs[:, 1]), add)
    return jnp.stack([s.hi, s.lo], axis=-1)


# ----------------------------------------------------------------------------
# functional dispatch (mirrors sketch.dispatch.update_registers)
# ----------------------------------------------------------------------------


def update_bank_registers(
    registers: jnp.ndarray,
    keys: jnp.ndarray,
    items: jnp.ndarray,
    cfg: HLLConfig,
    plan: Optional[ExecutionPlan] = None,
) -> jnp.ndarray:
    """Keyed scatter-max of ``items`` into a raw (B, m) register bank.

    The bank-capable backend registered under ``plan.backend`` runs the
    fused update; placement="mesh" shards the (keys, items) pair through
    the same :func:`repro.sketch.dispatch.mesh_fold` rule as the
    single-sketch path (per-device partial banks + one lax.pmax fold,
    edge-padding for non-divisible streams); placement="sharded" splits
    the BANK'S ROW AXIS over the mesh instead and routes keys by
    re-basing them into each device's block (DESIGN.md §16) — the §9
    drop rule discards foreign keys, so no fold collective is needed
    and bit-identity to local holds row by row.
    """
    plan = (DEFAULT_PLAN if plan is None else plan).validate()
    backend = get_bank_backend(plan.backend)
    flat_keys = jnp.asarray(keys).reshape(-1).astype(jnp.int32)
    flat_items = jnp.asarray(items).reshape(-1)
    if flat_keys.shape[0] != flat_items.shape[0]:
        raise ValueError(
            f"keys ({flat_keys.shape[0]}) and items ({flat_items.shape[0]}) "
            f"must flatten to the same length"
        )
    if flat_items.shape[0] == 0 or registers.shape[0] == 0:
        # nothing to land (or nowhere to land it): no backend dispatch
        return registers
    if plan.placement == "local":
        return backend(registers, flat_keys, flat_items, cfg, plan)
    if plan.placement == "sharded":
        return row_shard_fold(
            plan,
            registers,
            flat_keys,
            (flat_items,),
            _sharded_ingest_fn(backend, cfg, plan),
        )
    return mesh_fold(
        plan,
        registers,
        (flat_keys, flat_items),
        lambda regs, ks, xs: backend(regs, ks, xs, cfg, plan),
    )


@functools.lru_cache(maxsize=256)
def _sharded_ingest_fn(backend, cfg: HLLConfig, plan: ExecutionPlan):
    """Identity-stable block ingest for the sharded-placement cache.

    The dispatch layer memoizes the jitted ``shard_map`` callable per
    apply-function IDENTITY; an inline lambda here would defeat that and
    re-trace on every serve tick, so the closure itself is cached on the
    values it closes over (registry fns, ``cfg`` and ``plan`` hash).
    """

    def apply(regs, ks, xs):
        return backend(regs, ks, xs, cfg, plan)

    return apply


@functools.lru_cache(maxsize=256)
def _sharded_estimate_fn(cfg: HLLConfig, name: Optional[str]):
    """Identity-stable per-row-block estimate map (read-side companion)."""
    from repro.sketch import estimators as _estimators

    def apply(regs):
        return _estimators.estimate_many(regs, cfg, estimator=name)

    return apply


# ----------------------------------------------------------------------------
# the carrier
# ----------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SketchBank:
    """B same-config sketches as one pytree: the multi-tenant carrier."""

    registers: jnp.ndarray  # (B, m) uint8
    n_items: jnp.ndarray  # (B, 2) uint32 limb pairs, exact per-row counts
    cfg: HLLConfig = dataclasses.field(metadata=dict(static=True))

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def empty(cls, rows: int, cfg: Optional[HLLConfig] = None) -> "SketchBank":
        cfg = cfg or HLLConfig()
        if rows < 1:
            raise ValueError(f"a bank needs at least one row, got {rows}")
        return cls(
            jnp.zeros((rows, cfg.m), hll.REGISTER_DTYPE),
            jnp.zeros((rows, 2), jnp.uint32),
            cfg,
        )

    @classmethod
    def from_sketches(cls, sketches: Sequence[HyperLogLog]) -> "SketchBank":
        """Stack same-config carriers into one bank (counters preserved)."""
        if not sketches:
            raise ValueError("from_sketches needs at least one sketch")
        cfg = sketches[0].cfg
        for sk in sketches[1:]:
            if sk.cfg != cfg:
                raise ValueError(f"bank rows must share one config: {sk.cfg} vs {cfg}")
        return cls(
            jnp.stack([sk.registers for sk in sketches]),
            jnp.stack([sk.n_items for sk in sketches]),
            cfg,
        )

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    def __len__(self) -> int:
        return int(self.registers.shape[0])

    def row(self, i: int) -> HyperLogLog:
        """Row ``i`` as a standalone carrier (registers + exact counter)."""
        rows = len(self)
        if not -rows <= i < rows:
            # jnp indexing would silently clamp and hand back the edge row
            raise IndexError(f"row {i} out of range for a {rows}-row bank")
        return HyperLogLog(self.registers[i], self.n_items[i], self.cfg)

    def to_sketches(self) -> list:
        return [self.row(i) for i in range(len(self))]

    @property
    def counts(self) -> np.ndarray:
        """(B,) exact per-row observation counts as uint64."""
        limbs = np.asarray(self.n_items)
        hi = limbs[:, 0].astype(np.uint64)
        lo = limbs[:, 1].astype(np.uint64)
        return (hi << np.uint64(32)) | lo

    @property
    def nbytes(self) -> int:
        """Storage footprint of the dense representation."""
        return int(self.registers.nbytes + self.n_items.nbytes)

    def density(self) -> dict:
        """Storage introspection, schema-compatible with the hybrid bank's.

        A dense bank is all-dense by construction; ``occupancy_mean``
        reports how full the registers actually are, which is what decides
        whether ``to_hybrid()`` would pay off (DESIGN.md §12).
        """
        rows = len(self)
        occ = (np.asarray(self.registers) > 0).sum(axis=1)
        return {
            "rows": rows,
            "dense_rows": rows,
            "sparse_rows": 0,
            "capacity": 0,
            "threshold": None,
            "occupancy_mean": float(occ.mean() / self.cfg.m) if rows else 0.0,
            "nbytes": self.nbytes,
            "dense_nbytes": self.nbytes,
            "reduction": 1.0,
        }

    def to_hybrid(self, threshold: Optional[int] = None, dense_rows=None):
        """Demote to the sparse/dense ``HybridBank`` layout (DESIGN.md §12)."""
        from repro.sketch.sparse import HybridBank

        return HybridBank.from_dense(self, threshold, dense_rows=dense_rows)

    # ------------------------------------------------------------------
    # aggregation (paper phase 3, bank-wide)
    # ------------------------------------------------------------------

    def update_many(
        self,
        keys: jnp.ndarray,
        items: jnp.ndarray,
        plan: Optional[ExecutionPlan] = None,
    ) -> "SketchBank":
        """Route each item to row ``keys[i]`` and apply one fused update.

        A zero-length stream returns ``self`` without dispatching any
        backend (and without touching the counters); so does a zero-row
        bank, where every key is out of range by definition.
        """
        flat_keys = jnp.asarray(keys).reshape(-1).astype(jnp.int32)
        flat_items = jnp.asarray(items).reshape(-1)
        if flat_keys.shape[0] != flat_items.shape[0]:
            raise ValueError(
                f"keys ({flat_keys.shape[0]}) and items ({flat_items.shape[0]}) "
                f"must flatten to the same length"
            )
        if flat_items.shape[0] == 0 or len(self) == 0:
            return self
        obs_metrics.observe("bank.update_many.batch_items", flat_items.shape[0])
        regs = update_bank_registers(self.registers, flat_keys, items, self.cfg, plan)
        rows = len(self)
        # count only the observations that actually landed (dropped keys
        # must not inflate a row's exact counter)
        routed = jnp.where((flat_keys >= 0) & (flat_keys < rows), flat_keys, rows)
        counts = jnp.bincount(routed, length=rows + 1)[:rows]
        return dataclasses.replace(
            self,
            registers=regs,
            n_items=_counter_add_rows(self.n_items, counts),
        )

    def merge(self, other: "SketchBank") -> "SketchBank":
        """Row-wise Merge-buckets fold; counters add exactly."""
        if self.cfg != other.cfg:
            raise ValueError(
                f"cannot merge banks with different configs: "
                f"{self.cfg} vs {other.cfg}"
            )
        if len(self) != len(other):
            raise ValueError(
                f"cannot merge banks of different sizes: "
                f"{len(self)} vs {len(other)} rows"
            )
        limbs = u64lib.add(
            u64lib.U64(self.n_items[:, 0], self.n_items[:, 1]),
            u64lib.U64(other.n_items[:, 0], other.n_items[:, 1]),
        )
        return dataclasses.replace(
            self,
            registers=jnp.maximum(self.registers, other.registers),
            n_items=jnp.stack([limbs.hi, limbs.lo], axis=-1),
        )

    __or__ = merge

    # ------------------------------------------------------------------
    # estimation (paper phase 4, batched)
    # ------------------------------------------------------------------

    def estimate_many(
        self,
        estimator: Optional[str] = None,
        plan: Optional[ExecutionPlan] = None,
    ) -> jnp.ndarray:
        """(B,) float32 estimates in one jitted dispatch (DESIGN.md §8).

        A zero-row bank short-circuits to an empty result instead of
        tracing a degenerate zero-batch histogram.  Under a
        placement="sharded" ``plan`` each device finalizes its own row
        block (DESIGN.md §16) — the histogram is per-row, so the blocked
        read is bit-identical to the flat one.
        """
        from repro.sketch import estimators as _estimators

        if len(self) == 0:
            return jnp.zeros((0,), jnp.float32)
        name = estimator
        if plan is not None:
            plan = plan.validate()
            name = estimator or plan.estimator
            if plan.placement == "sharded":
                return row_shard_apply(
                    plan,
                    _sharded_estimate_fn(self.cfg, name),
                    (self.registers,),
                    (0,),
                )
        return _estimators.estimate_many(self.registers, self.cfg, estimator=name)

    def estimate(self, i: int, estimator: Optional[str] = None) -> float:
        """Exact host-side estimate of one row."""
        return self.row(i).estimate(estimator)

    # ------------------------------------------------------------------
    # serialization (DESIGN.md §7, bank framing)
    # ------------------------------------------------------------------

    def to_bytes(self) -> bytes:
        """20-byte bank header + B uint64 counts + B*m register bytes."""
        header = _BANK_HEADER.pack(
            _BANK_MAGIC,
            _BANK_VERSION,
            self.cfg.p,
            self.cfg.hash_bits,
            0,
            self.cfg.seed,
            len(self),
        )
        counts = self.counts.astype("<u8").tobytes()
        regs = np.asarray(self.registers, dtype=np.uint8)
        return header + counts + regs.tobytes()

    @classmethod
    def from_bytes(cls, data: bytes) -> "SketchBank":
        if len(data) < _BANK_HEADER.size:
            raise ValueError(f"truncated bank: {len(data)} bytes")
        magic, version, p, hash_bits, _flags, seed, rows = _BANK_HEADER.unpack(
            data[: _BANK_HEADER.size]
        )
        if magic != _BANK_MAGIC:
            raise ValueError(f"bad magic {magic!r}; not a serialized bank")
        if version != _BANK_VERSION:
            hint = (
                "; version 2 is the hybrid sparse format — parse it with "
                "repro.sketch.sparse.HybridBank.from_bytes"
                if version == 2
                else ""
            )
            raise ValueError(f"unsupported bank version {version}{hint}")
        if rows < 1:
            raise ValueError(f"bank header claims {rows} rows")
        cfg = HLLConfig(p=p, hash_bits=hash_bits, seed=seed)
        counts_end = _BANK_HEADER.size + rows * _ROW_COUNT.size
        expected = counts_end + rows * cfg.m
        if len(data) != expected:
            raise ValueError(
                f"bank payload is {len(data)} bytes, expected {expected} "
                f"for {rows} rows of m={cfg.m}"
            )
        raw_counts = np.frombuffer(data[_BANK_HEADER.size : counts_end], dtype="<u8")
        limbs = np.stack(
            [(raw_counts >> 32).astype(np.uint32), raw_counts.astype(np.uint32)],
            axis=-1,
        )
        regs = np.frombuffer(data[counts_end:], dtype=np.uint8).reshape(rows, cfg.m)
        return cls(jnp.asarray(regs.copy()), jnp.asarray(limbs), cfg)


# ----------------------------------------------------------------------------
# the batched entry point named by the roadmap
# ----------------------------------------------------------------------------


def update_many(
    bank: SketchBank,
    keys: jnp.ndarray,
    items: jnp.ndarray,
    plan: Optional[ExecutionPlan] = None,
) -> SketchBank:
    """Batched multi-tenant ingestion: one fused dispatch for the bank."""
    return bank.update_many(keys, items, plan)
