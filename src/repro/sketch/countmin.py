"""CountMinBank: heavy-hitter (frequency) sketches on the registry spine.

The paper's thesis — sketch ingest is one fused scatter over a register
file — holds for frequency sketches just as it does for HyperLogLog
(arXiv:2504.16896 runs count-min banks through the same FPGA datapath).
This module is the second tenant of the spine PRs 1-5 built: a
``CountMinBank`` carries B per-tenant count-min sketches as one frozen
pytree — a (B, d, w) uint32 counter bank plus a Topkapi-style (B, d, w)
label table — and every verb dispatches through the same ``ExecutionPlan``
registries as the HLL family (DESIGN.md §13).

Count-min core (Cormode & Muthukrishnan): each item increments one cell
per depth row, at the column picked by an independent hash; a point query
reads the d cells back and takes the min (an upper bound on the true
count, off by at most the collision mass).  The d hashes derive from ONE
murmur3_64 evaluation by Kirsch-Mitzenmacher double hashing —
``idx_r = (h.lo + r * h.hi) mod w`` in uint32 — so ingest hashes exactly
as cheaply as the HLL path.

Top-k recovery follows Topkapi (NeurIPS 2018): each cell carries a
(label, label_count) majority-vote pair next to its counter, and the
heavy hitters are recovered by querying the surviving labels.  The
classical per-item vote is order-dependent, which would break the
bit-identity contract under fused/tiled ingest, so ``update_many``
applies a BATCH-CANONICAL vote instead: per update call and per cell,
the batch winner is the max-multiplicity item (ties to the larger
value), its surplus ``s = 2*mc - total`` is the net vote of any serial
order, and the stored pair absorbs (winner, s) with the deterministic
rules documented on ``_label_update``.  The vote is one shared jnp
routine across ALL backends — backends differ only on the counter
scatter — so label state is bit-identical by construction.

Key routing, drop rules, exact per-row observation counters, and the
zero-length/zero-row short-circuits mirror ``SketchBank`` (DESIGN.md §9).
``WindowedCountMinBank`` rides the same epoch-ring contract as
``WindowedBank`` (DESIGN.md §11) with a fused window SUM-fold.  The wire
formats are RCMB/RCMW, strict-rejection siblings of RHLB/RHLW.
"""

from __future__ import annotations

import dataclasses
import struct
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.sketch import murmur3, u64 as u64lib
from repro.sketch.bank import _counter_add_rows
from repro.sketch.dispatch import cm_mesh_sum
from repro.sketch.plan import (
    DEFAULT_PLAN,
    ExecutionPlan,
    get_cm_backend,
    get_cm_window_backend,
)
from repro.sketch.window import _initial_epochs, _validate_epoch_ring

COUNTER_DTYPE = jnp.uint32
LABEL_DTYPE = jnp.int32

_CM_HEADER = struct.Struct("<4sBBHQII")  # magic, ver, depth, flags, seed, w, B
_CM_MAGIC = b"RCMB"
_CM_VERSION = 1
_ROW_COUNT = struct.Struct("<Q")

_CMW_HEADER = struct.Struct("<4sBBHQIIII")
# magic, ver, depth, flags, seed, width, W, B, cursor
_CMW_MAGIC = b"RCMW"
_CMW_VERSION = 1
_EPOCH = np.dtype("<i4")


@dataclasses.dataclass(frozen=True)
class CMConfig:
    """Static count-min parameters: d depth rows x w counters per row.

    The classical guarantees: a point query overestimates by at most
    ``2n/w`` with probability ``1 - 2^-d`` (n = stream length), so width
    buys accuracy and depth buys confidence.  ``seed`` feeds the single
    murmur3_64 evaluation both derived hash families share.
    """

    depth: int = 4
    width: int = 1024
    seed: int = 0

    def __post_init__(self):
        if not 1 <= self.depth <= 16:
            raise ValueError(f"depth must be in [1,16], got {self.depth}")
        if not 1 <= self.width <= 1 << 24:
            raise ValueError(f"width must be in [1, 2^24], got {self.width}")
        if not 0 <= self.seed < 1 << 64:
            # keeps the serialized header (uint64 seed) total, like HLLConfig
            raise ValueError(f"seed must be a uint64, got {self.seed}")

    @property
    def cells(self) -> int:
        return self.depth * self.width

    @property
    def memory_footprint_bits(self) -> int:
        # counter + label + label_count, all 32-bit, per cell
        return self.cells * 3 * 32


def cm_hash_index(items: jnp.ndarray, cfg: CMConfig) -> jnp.ndarray:
    """The d column indices of each item: (d, n) int32 in [0, w).

    Kirsch-Mitzenmacher double hashing over the two uint32 limbs of one
    murmur3_64 evaluation: ``idx_r = (h.lo + r * h.hi) mod w``, computed
    entirely in uint32 so the very same arithmetic lowers on TPU.
    """
    h = murmur3.murmur3_64(items.reshape(-1), cfg.seed)
    r = jnp.arange(cfg.depth, dtype=jnp.uint32)[:, None]
    mixed = h.lo[None, :] + r * h.hi[None, :]
    return (mixed % jnp.uint32(cfg.width)).astype(jnp.int32)


# ----------------------------------------------------------------------------
# functional dispatch (mirrors bank.update_bank_registers)
# ----------------------------------------------------------------------------


def update_cm_counters(
    counters: jnp.ndarray,
    keys: jnp.ndarray,
    items: jnp.ndarray,
    cfg: CMConfig,
    plan: Optional[ExecutionPlan] = None,
) -> jnp.ndarray:
    """Keyed scatter-add of ``items`` into a raw (B, d, w) counter bank.

    The cm-capable backend registered under ``plan.backend`` runs the
    fused ingest; placement="mesh" shards the (keys, items) pair through
    :func:`repro.sketch.dispatch.cm_mesh_sum` (per-device zero-based
    deltas + one lax.psum; drop-key padding, because edge-padding would
    double-count under a sum).
    """
    plan = (DEFAULT_PLAN if plan is None else plan).validate()
    backend = get_cm_backend(plan.backend)
    flat_keys = jnp.asarray(keys).reshape(-1).astype(jnp.int32)
    flat_items = jnp.asarray(items).reshape(-1)
    if flat_keys.shape[0] != flat_items.shape[0]:
        raise ValueError(
            f"keys ({flat_keys.shape[0]}) and items ({flat_items.shape[0]}) "
            f"must flatten to the same length"
        )
    if flat_items.shape[0] == 0 or counters.shape[0] == 0:
        # nothing to land (or nowhere to land it): no backend dispatch
        return counters
    if plan.placement == "local":
        return backend.ingest(counters, flat_keys, flat_items, cfg, plan)
    return cm_mesh_sum(
        plan,
        counters,
        (flat_keys, flat_items),
        lambda cnt, ks, xs: backend.ingest(cnt, ks, xs, cfg, plan),
    )


def query_cm_counters(
    counters: jnp.ndarray,
    items: jnp.ndarray,
    cfg: CMConfig,
    plan: Optional[ExecutionPlan] = None,
) -> jnp.ndarray:
    """(B, n) point-query estimates of ``items`` against every bank row.

    Queries read replicated counter state, so mesh plans query locally —
    placement only moves ingest streams.  Zero-length probes and zero-row
    banks short-circuit without dispatching any backend.
    """
    plan = (DEFAULT_PLAN if plan is None else plan).validate()
    backend = get_cm_backend(plan.backend)
    flat = jnp.asarray(items).reshape(-1)
    rows = counters.shape[0]
    if rows == 0:
        return jnp.zeros((0, flat.shape[0]), counters.dtype)
    if flat.shape[0] == 0:
        return jnp.zeros((rows, 0), counters.dtype)
    return backend.query(counters, flat, cfg, plan)


# ----------------------------------------------------------------------------
# Topkapi label voting (shared jnp routine — every backend bit-identical)
# ----------------------------------------------------------------------------


def _merge_label_tables(
    l1: jnp.ndarray, c1: jnp.ndarray, l2: jnp.ndarray, c2: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Topkapi cell merge: same labels add, differing labels fight.

    Same label -> counts add.  Different labels -> the bigger count wins
    and keeps the difference; an exact tie keeps the larger label value
    with count 0 (deterministic and symmetric, so ``a | b == b | a``).
    """
    same = l1 == l2
    lab_diff = jnp.where(c1 > c2, l1, jnp.where(c2 > c1, l2, jnp.maximum(l1, l2)))
    label = jnp.where(same, l1, lab_diff)
    count = jnp.where(same, c1 + c2, jnp.abs(c1 - c2))
    return label, count


@partial(jax.jit, static_argnames=("cfg",))
def _label_update(
    labels: jnp.ndarray,
    label_counts: jnp.ndarray,
    keys: jnp.ndarray,
    items: jnp.ndarray,
    cfg: CMConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One batch-canonical Topkapi vote over every touched cell.

    Per cell, over THIS batch: the winner ``x*`` is the item with the
    highest multiplicity ``mc`` among the batch's hits (ties to the
    larger item value) and its surplus is ``s = 2*mc - total`` — the net
    count a serial majority vote would leave if every non-winner vote
    cancelled a winner vote.  The stored (l, lc) pair then absorbs
    (x*, s) deterministically:

      lc == 0      -> the cell is vacant: (x*, max(s, 0))
      x* == l      -> votes reinforce:    (l, max(lc + s, 0))
      otherwise    -> t = s - lc decides: t > 0 -> (x*, t)
                                          t < 0 -> (l, -t)
                                          t == 0 -> (max(l, x*), 0)

    Cells with no valid hits this batch are untouched.  The rule is a
    pure function of the batch MULTISET, so every backend and every tile
    order yields bit-identical label state.
    """
    rows, depth, width = labels.shape
    cells = depth * width
    total_cells = rows * cells
    idx = cm_hash_index(items, cfg)  # (d, n)
    valid = (keys >= 0) & (keys < rows)
    lane = jnp.arange(depth, dtype=jnp.int32)[:, None] * width
    cell = jnp.where(
        valid[None, :], keys[None, :] * cells + lane + idx, total_cells
    ).reshape(-1)
    vals = jnp.broadcast_to(
        items.astype(LABEL_DTYPE)[None, :], idx.shape
    ).reshape(-1)

    # per-(cell, value) multiplicity via one lexsort + run-length count
    order = jnp.lexsort((vals, cell))
    sc = cell[order]
    sv = vals[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), (sc[1:] != sc[:-1]) | (sv[1:] != sv[:-1])]
    )
    run_id = jnp.cumsum(first.astype(jnp.int32)) - 1
    run_len = jax.ops.segment_sum(
        jnp.ones_like(run_id), run_id, num_segments=sc.shape[0]
    )
    pc = run_len[run_id]  # multiplicity of each element's (cell, value) pair

    live = sc < total_cells
    neg = jnp.iinfo(jnp.int32).min
    total_f = jax.ops.segment_sum(
        live.astype(jnp.int32), sc, num_segments=total_cells + 1
    )
    mc_f = jax.ops.segment_max(
        jnp.where(live, pc, neg), sc, num_segments=total_cells + 1
    )
    is_best = live & (pc == mc_f[sc])
    winner_f = jax.ops.segment_max(
        jnp.where(is_best, sv, neg), sc, num_segments=total_cells + 1
    )
    total = total_f[:total_cells]
    mc = jnp.maximum(mc_f[:total_cells], 0)
    winner = winner_f[:total_cells]

    s = 2 * mc - total
    l = labels.reshape(total_cells)
    lc = label_counts.reshape(total_cells)
    vacant = lc == 0
    same = winner == l
    t = s - lc
    new_l = jnp.where(
        vacant,
        winner,
        jnp.where(
            same,
            l,
            jnp.where(t > 0, winner, jnp.where(t < 0, l, jnp.maximum(l, winner))),
        ),
    )
    new_c = jnp.where(
        vacant,
        jnp.maximum(s, 0),
        jnp.where(same, jnp.maximum(lc + s, 0), jnp.abs(t)),
    )
    touched = total > 0
    out_l = jnp.where(touched, new_l, l).reshape(rows, depth, width)
    out_c = jnp.where(touched, new_c, lc).reshape(rows, depth, width)
    return out_l, out_c


@partial(jax.jit, static_argnames=("cfg",))
def _query_rowwise(
    counters: jnp.ndarray, cand: jnp.ndarray, cfg: CMConfig
) -> jnp.ndarray:
    """Estimate (B, C) per-row candidates against their OWN rows only."""
    rows, depth, width = counters.shape
    n_cand = cand.shape[1]
    idx = cm_hash_index(cand.reshape(-1), cfg).reshape(depth, rows, n_cand)
    b = jnp.arange(rows, dtype=jnp.int32)[:, None, None]
    r = jnp.arange(depth, dtype=jnp.int32)[None, :, None]
    gathered = counters[b, r, jnp.transpose(idx, (1, 0, 2))]  # (B, d, C)
    return jnp.min(gathered, axis=1)


# ----------------------------------------------------------------------------
# the carrier
# ----------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CountMinBank:
    """B same-config count-min sketches (+ Topkapi labels) as one pytree."""

    counters: jnp.ndarray  # (B, d, w) uint32
    labels: jnp.ndarray  # (B, d, w) int32 Topkapi majority labels
    label_counts: jnp.ndarray  # (B, d, w) int32 majority-vote counts
    n_items: jnp.ndarray  # (B, 2) uint32 limb pairs, exact per-row counts
    cfg: CMConfig = dataclasses.field(metadata=dict(static=True))

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def empty(cls, rows: int, cfg: Optional[CMConfig] = None) -> "CountMinBank":
        cfg = cfg or CMConfig()
        if rows < 1:
            raise ValueError(f"a bank needs at least one row, got {rows}")
        shape = (rows, cfg.depth, cfg.width)
        return cls(
            jnp.zeros(shape, COUNTER_DTYPE),
            jnp.zeros(shape, LABEL_DTYPE),
            jnp.zeros(shape, LABEL_DTYPE),
            jnp.zeros((rows, 2), jnp.uint32),
            cfg,
        )

    def with_rows(self, rows: int) -> "CountMinBank":
        """Grow the bank axis to ``rows`` (new rows start empty)."""
        have = len(self)
        if rows < have:
            raise ValueError(f"cannot shrink a {have}-row bank to {rows}")
        if rows == have:
            return self
        grow = ((0, rows - have),) + ((0, 0),) * 2
        return dataclasses.replace(
            self,
            counters=jnp.pad(self.counters, grow),
            labels=jnp.pad(self.labels, grow),
            label_counts=jnp.pad(self.label_counts, grow),
            n_items=jnp.pad(self.n_items, ((0, rows - have), (0, 0))),
        )

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    def __len__(self) -> int:
        return int(self.counters.shape[0])

    @property
    def counts(self) -> np.ndarray:
        """(B,) exact per-row observation counts as uint64."""
        limbs = np.asarray(self.n_items)
        hi = limbs[:, 0].astype(np.uint64)
        lo = limbs[:, 1].astype(np.uint64)
        return (hi << np.uint64(32)) | lo

    @property
    def nbytes(self) -> int:
        return int(
            self.counters.nbytes
            + self.labels.nbytes
            + self.label_counts.nbytes
            + self.n_items.nbytes
        )

    # ------------------------------------------------------------------
    # aggregation (paper phase 3, frequency flavor)
    # ------------------------------------------------------------------

    def update_many(
        self,
        keys: jnp.ndarray,
        items: jnp.ndarray,
        plan: Optional[ExecutionPlan] = None,
    ) -> "CountMinBank":
        """Route each item to row ``keys[i]``: one fused d-hash scatter-add.

        Counters go through the cm backend registered under
        ``plan.backend`` (one segment-sum / Pallas scatter for the whole
        batch); the Topkapi label vote is the shared jnp routine, always
        on the full stream, so label state cannot drift across backends
        or placements.  A zero-length stream or a zero-row bank returns
        ``self`` without dispatching anything.
        """
        flat_keys = jnp.asarray(keys).reshape(-1).astype(jnp.int32)
        flat_items = jnp.asarray(items).reshape(-1)
        if flat_keys.shape[0] != flat_items.shape[0]:
            raise ValueError(
                f"keys ({flat_keys.shape[0]}) and items ({flat_items.shape[0]}) "
                f"must flatten to the same length"
            )
        if flat_items.shape[0] == 0 or len(self) == 0:
            return self
        obs_metrics.observe("cm.update_many.batch_items", flat_items.shape[0])
        counters = update_cm_counters(
            self.counters, flat_keys, flat_items, self.cfg, plan
        )
        labels, label_counts = _label_update(
            self.labels, self.label_counts, flat_keys, flat_items, self.cfg
        )
        rows = len(self)
        routed = jnp.where((flat_keys >= 0) & (flat_keys < rows), flat_keys, rows)
        landed = jnp.bincount(routed, length=rows + 1)[:rows]
        return dataclasses.replace(
            self,
            counters=counters,
            labels=labels,
            label_counts=label_counts,
            n_items=_counter_add_rows(self.n_items, landed),
        )

    def merge(self, other: "CountMinBank") -> "CountMinBank":
        """Cell-wise counter sum + Topkapi label merge; counters are exact
        mod 2^32 and the exact observation counters add to 2^64."""
        if self.cfg != other.cfg:
            raise ValueError(
                f"cannot merge banks with different configs: "
                f"{self.cfg} vs {other.cfg}"
            )
        if len(self) != len(other):
            raise ValueError(
                f"cannot merge banks of different sizes: "
                f"{len(self)} vs {len(other)} rows"
            )
        labels, label_counts = _merge_label_tables(
            self.labels, self.label_counts, other.labels, other.label_counts
        )
        limbs = u64lib.add(
            u64lib.U64(self.n_items[:, 0], self.n_items[:, 1]),
            u64lib.U64(other.n_items[:, 0], other.n_items[:, 1]),
        )
        return dataclasses.replace(
            self,
            counters=self.counters + other.counters,
            labels=labels,
            label_counts=label_counts,
            n_items=jnp.stack([limbs.hi, limbs.lo], axis=-1),
        )

    __or__ = merge

    # ------------------------------------------------------------------
    # queries (paper phase 4, frequency flavor)
    # ------------------------------------------------------------------

    def query(
        self,
        items: jnp.ndarray,
        plan: Optional[ExecutionPlan] = None,
    ) -> jnp.ndarray:
        """(B, n) estimated counts of each probe item in every row."""
        return query_cm_counters(self.counters, items, self.cfg, plan)

    def topk(self, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Per-row heavy hitters from the Topkapi label slots.

        Candidates are the d*w surviving labels of each row — any item
        that dominated at least one of its cells is present, which is
        what makes recall high for genuinely heavy items — deduplicated
        and ranked by their count-min estimate (one batched device
        gather; the top-k selection itself is host-side finalization,
        like the exact estimate paths).

        Returns ``(values, counts)`` as (B, k) int32 / uint64 arrays,
        ranked by descending estimate (ties to the larger value); rows
        with fewer than k distinct labels pad with value -1 / count 0.
        """
        if k < 1:
            raise ValueError(f"topk needs k >= 1, got {k}")
        rows = len(self)
        values = np.full((rows, k), -1, np.int32)
        counts = np.zeros((rows, k), np.uint64)
        if rows == 0:
            return values, counts
        cand = self.labels.reshape(rows, -1)
        ests = np.asarray(_query_rowwise(self.counters, cand, self.cfg))
        cand = np.asarray(cand)
        for b in range(rows):
            uniq, where_first = np.unique(cand[b], return_index=True)
            est = ests[b][where_first].astype(np.uint64)
            top = np.lexsort((uniq, est))[::-1][:k]
            values[b, : top.size] = uniq[top]
            counts[b, : top.size] = est[top]
        return values, counts

    # ------------------------------------------------------------------
    # serialization (RCMB: strict sibling of RHLB)
    # ------------------------------------------------------------------

    def to_bytes(self) -> bytes:
        """24-byte header + B uint64 counts + counter/label/vote tables."""
        header = _CM_HEADER.pack(
            _CM_MAGIC,
            _CM_VERSION,
            self.cfg.depth,
            0,
            self.cfg.seed,
            self.cfg.width,
            len(self),
        )
        counts = self.counts.astype("<u8").tobytes()
        return (
            header
            + counts
            + np.asarray(self.counters, np.uint32).astype("<u4").tobytes()
            + np.asarray(self.labels, np.int32).astype("<i4").tobytes()
            + np.asarray(self.label_counts, np.int32).astype("<i4").tobytes()
        )

    @classmethod
    def from_bytes(cls, data: bytes) -> "CountMinBank":
        if len(data) < _CM_HEADER.size:
            raise ValueError(f"truncated count-min bank: {len(data)} bytes")
        magic, version, depth, _flags, seed, width, rows = _CM_HEADER.unpack(
            data[: _CM_HEADER.size]
        )
        if magic != _CM_MAGIC:
            raise ValueError(
                f"bad magic {magic!r}; not a serialized count-min bank"
            )
        if version != _CM_VERSION:
            raise ValueError(f"unsupported count-min bank version {version}")
        if rows < 1:
            raise ValueError(f"count-min header claims {rows} rows")
        cfg = CMConfig(depth=depth, width=width, seed=seed)
        cells = rows * cfg.cells
        counts_end = _CM_HEADER.size + rows * _ROW_COUNT.size
        expected = counts_end + 3 * 4 * cells
        if len(data) != expected:
            # covers payloads cut anywhere: mid-counts, mid-counter, and
            # mid-label-table alike
            raise ValueError(
                f"count-min payload is {len(data)} bytes, expected "
                f"{expected} for {rows} rows of d={depth}, w={width}"
            )
        raw_counts = np.frombuffer(data[_CM_HEADER.size : counts_end], "<u8")
        limbs = np.stack(
            [(raw_counts >> 32).astype(np.uint32), raw_counts.astype(np.uint32)],
            axis=-1,
        )
        shape = (rows, cfg.depth, cfg.width)
        cnt_end = counts_end + 4 * cells
        lab_end = cnt_end + 4 * cells
        counters = np.frombuffer(data[counts_end:cnt_end], "<u4").reshape(shape)
        labels = np.frombuffer(data[cnt_end:lab_end], "<i4").reshape(shape)
        votes = np.frombuffer(data[lab_end:], "<i4").reshape(shape)
        return cls(
            jnp.asarray(counters.copy()),
            jnp.asarray(labels.copy()),
            jnp.asarray(votes.copy()),
            jnp.asarray(limbs),
            cfg,
        )


# ----------------------------------------------------------------------------
# the windowed ring (DESIGN.md §11 contract, sum-fold flavor)
# ----------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WindowedCountMinBank:
    """A (W, B, d, w) ring of time-bucket count-min banks as one pytree.

    The ring/rotation contract is identical to ``WindowedBank`` (epoch
    labels, cursor, expiry-on-overwrite, monotone ``advance_to``); the
    window fold differs in lattice only — counters SUM over the live
    buckets (counts are additive across disjoint time slices) and label
    tables merge pairwise with the Topkapi rule in slot order.
    """

    counters: jnp.ndarray  # (W, B, d, w) uint32
    labels: jnp.ndarray  # (W, B, d, w) int32
    label_counts: jnp.ndarray  # (W, B, d, w) int32
    n_items: jnp.ndarray  # (W, B, 2) uint32 limb pairs per bucket row
    cursor: jnp.ndarray  # () int32: ring slot of the newest epoch
    epochs: jnp.ndarray  # (W,) int32: absolute epoch held by each slot
    cfg: CMConfig = dataclasses.field(metadata=dict(static=True))

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def empty(
        cls, window: int, rows: int, cfg: Optional[CMConfig] = None
    ) -> "WindowedCountMinBank":
        cfg = cfg or CMConfig()
        if window < 1:
            raise ValueError(f"a window needs at least one bucket, got {window}")
        if rows < 1:
            raise ValueError(f"a bank needs at least one row, got {rows}")
        shape = (window, rows, cfg.depth, cfg.width)
        return cls(
            jnp.zeros(shape, COUNTER_DTYPE),
            jnp.zeros(shape, LABEL_DTYPE),
            jnp.zeros(shape, LABEL_DTYPE),
            jnp.zeros((window, rows, 2), jnp.uint32),
            jnp.zeros((), jnp.int32),
            jnp.asarray(_initial_epochs(window)),
            cfg,
        )

    def with_rows(self, rows: int) -> "WindowedCountMinBank":
        """Grow the bank axis to ``rows`` (new rows start empty)."""
        have = self.rows
        if rows < have:
            raise ValueError(f"cannot shrink a {have}-row window to {rows}")
        if rows == have:
            return self
        grow = ((0, 0), (0, rows - have)) + ((0, 0),) * 2
        return dataclasses.replace(
            self,
            counters=jnp.pad(self.counters, grow),
            labels=jnp.pad(self.labels, grow),
            label_counts=jnp.pad(self.label_counts, grow),
            n_items=jnp.pad(self.n_items, ((0, 0), (0, rows - have), (0, 0))),
        )

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    @property
    def window(self) -> int:
        return int(self.counters.shape[0])

    @property
    def rows(self) -> int:
        return int(self.counters.shape[1])

    def __len__(self) -> int:
        return self.rows

    @property
    def epoch(self) -> int:
        """The newest (current) absolute epoch — host-side read."""
        return int(self.epochs[self.cursor])

    @property
    def counts(self) -> np.ndarray:
        """(W, B) exact per-bucket-per-row observation counts as uint64."""
        limbs = np.asarray(self.n_items)
        hi = limbs[..., 0].astype(np.uint64)
        lo = limbs[..., 1].astype(np.uint64)
        return (hi << np.uint64(32)) | lo

    def window_counts(self, last_k: Optional[int] = None) -> np.ndarray:
        """(B,) exact observation counts over the last ``last_k`` epochs."""
        mask = np.asarray(self._live_mask(self._check_last_k(last_k)))
        return self.counts[mask].sum(axis=0, dtype=np.uint64)

    def _check_last_k(self, last_k: Optional[int]) -> int:
        if last_k is None:
            return self.window
        if not 1 <= int(last_k) <= self.window:
            raise ValueError(f"last_k must be in [1, {self.window}], got {last_k}")
        return int(last_k)

    def _live_mask(self, last_k: int) -> jnp.ndarray:
        """(W,) bool: slots holding one of the ``last_k`` newest epochs."""
        newest = self.epochs[self.cursor]
        return self.epochs > newest - last_k

    # ------------------------------------------------------------------
    # ingestion (current bucket)
    # ------------------------------------------------------------------

    def observe(
        self,
        keys: jnp.ndarray,
        items: jnp.ndarray,
        plan: Optional[ExecutionPlan] = None,
    ) -> "WindowedCountMinBank":
        """Route each item to row ``keys[i]`` of the CURRENT time bucket.

        The current bucket IS a ``CountMinBank``, so ingest delegates to
        ``CountMinBank.update_many`` wholesale — the §9 validation, drop,
        counter, and short-circuit rules cannot drift from the flat path.
        """
        pick = lambda a: jax.lax.dynamic_index_in_dim(
            a, self.cursor, 0, keepdims=False
        )
        cur = CountMinBank(
            pick(self.counters),
            pick(self.labels),
            pick(self.label_counts),
            pick(self.n_items),
            self.cfg,
        )
        new = cur.update_many(keys, items, plan)
        if new is cur:  # the empty-stream short-circuit: nothing to write back
            return self
        put = lambda ring, slab: jax.lax.dynamic_update_index_in_dim(
            ring, slab, self.cursor, 0
        )
        return dataclasses.replace(
            self,
            counters=put(self.counters, new.counters),
            labels=put(self.labels, new.labels),
            label_counts=put(self.label_counts, new.label_counts),
            n_items=put(self.n_items, new.n_items),
        )

    # ------------------------------------------------------------------
    # rotation
    # ------------------------------------------------------------------

    def advance(self, steps: int = 1) -> "WindowedCountMinBank":
        """Open ``steps`` new epochs, expiring the buckets they overwrite."""
        if steps < 1:
            raise ValueError(f"advance needs steps >= 1, got {steps}")
        return self.advance_to(self.epochs[self.cursor] + steps)

    def advance_to(self, epoch) -> "WindowedCountMinBank":
        """Rotate forward so ``epoch`` is current; the past never returns.

        Same rules as ``WindowedBank.advance_to``: overwritten slots
        zero-fill (counters, labels, AND votes), jumps >= W expire the
        whole ring, and a target at or before the current epoch is a
        no-op.
        """
        target = jnp.maximum(jnp.asarray(epoch, jnp.int32), self.epochs[self.cursor])
        window = self.window
        slots = jnp.arange(window, dtype=jnp.int32)
        new_epochs = target - jnp.mod(target - slots, window)
        stale = new_epochs > self.epochs  # slots being overwritten
        wipe = lambda a: jnp.where(
            stale.reshape((window,) + (1,) * (a.ndim - 1)), 0, a
        ).astype(a.dtype)
        return dataclasses.replace(
            self,
            counters=wipe(self.counters),
            labels=wipe(self.labels),
            label_counts=wipe(self.label_counts),
            n_items=wipe(self.n_items),
            cursor=jnp.mod(target, window).astype(jnp.int32),
            epochs=new_epochs.astype(jnp.int32),
        )

    # ------------------------------------------------------------------
    # windowed queries
    # ------------------------------------------------------------------

    def fold_window(
        self,
        last_k: Optional[int] = None,
        plan: Optional[ExecutionPlan] = None,
    ) -> CountMinBank:
        """The ``last_k``-epoch suffix collapsed to a flat ``CountMinBank``.

        Counters fold with ONE fused masked SUM-reduce over the ring axis
        (the cm window backend registered under ``plan.backend`` — the
        fourth sibling of ``window_fold``); label tables merge pairwise
        in slot order with the shared Topkapi rule; the exact per-row
        counters sum the live buckets host-side.  A zero-row ring folds
        to a zero-row bank without dispatching any backend.
        """
        last_k = self._check_last_k(last_k)
        plan = (DEFAULT_PLAN if plan is None else plan).validate()
        mask = self._live_mask(last_k)
        if self.rows == 0:
            shape = (0, self.cfg.depth, self.cfg.width)
            return CountMinBank(
                jnp.zeros(shape, COUNTER_DTYPE),
                jnp.zeros(shape, LABEL_DTYPE),
                jnp.zeros(shape, LABEL_DTYPE),
                jnp.zeros((0, 2), jnp.uint32),
                self.cfg,
            )
        backend = get_cm_window_backend(plan.backend)
        counters = backend(self.counters, mask, self.cfg, plan)
        live = np.flatnonzero(np.asarray(mask))  # never empty: cursor is live
        labels = self.labels[int(live[0])]
        votes = self.label_counts[int(live[0])]
        for s in live[1:]:
            labels, votes = _merge_label_tables(
                labels, votes, self.labels[int(s)], self.label_counts[int(s)]
            )
        totals = self.window_counts(last_k)
        limbs = np.stack(
            [(totals >> np.uint64(32)).astype(np.uint32), totals.astype(np.uint32)],
            axis=-1,
        )
        return CountMinBank(counters, labels, votes, jnp.asarray(limbs), self.cfg)

    def query_window(
        self,
        items: jnp.ndarray,
        last_k: Optional[int] = None,
        plan: Optional[ExecutionPlan] = None,
    ) -> jnp.ndarray:
        """(B, n) estimated counts over the ``last_k`` newest epochs."""
        return self.fold_window(last_k, plan).query(items, plan)

    def topk_window(
        self,
        k: int,
        last_k: Optional[int] = None,
        plan: Optional[ExecutionPlan] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-row heavy hitters over the ``last_k`` newest epochs."""
        return self.fold_window(last_k, plan).topk(k)

    # ------------------------------------------------------------------
    # serialization (RCMW: window header + epochs + RCMB payloads)
    # ------------------------------------------------------------------

    def to_bytes(self) -> bytes:
        """32-byte window header + W int32 epochs + W RCMB bucket blobs."""
        header = _CMW_HEADER.pack(
            _CMW_MAGIC,
            _CMW_VERSION,
            self.cfg.depth,
            0,
            self.cfg.seed,
            self.cfg.width,
            self.window,
            self.rows,
            int(self.cursor),
        )
        epochs = np.asarray(self.epochs, dtype=_EPOCH).tobytes()
        buckets = b"".join(
            CountMinBank(
                self.counters[w],
                self.labels[w],
                self.label_counts[w],
                self.n_items[w],
                self.cfg,
            ).to_bytes()
            for w in range(self.window)
        )
        return header + epochs + buckets

    @classmethod
    def from_bytes(cls, data: bytes) -> "WindowedCountMinBank":
        if len(data) < _CMW_HEADER.size:
            raise ValueError(f"truncated count-min window: {len(data)} bytes")
        magic, version, depth, _flags, seed, width, window, rows, cursor = (
            _CMW_HEADER.unpack(data[: _CMW_HEADER.size])
        )
        if magic != _CMW_MAGIC:
            raise ValueError(
                f"bad magic {magic!r}; not a serialized count-min window"
            )
        if version != _CMW_VERSION:
            raise ValueError(f"unsupported count-min window version {version}")
        if window < 1 or rows < 1:
            raise ValueError(
                f"window header claims {window} buckets x {rows} rows"
            )
        if cursor >= window:
            raise ValueError(f"cursor {cursor} out of range for W={window}")
        cfg = CMConfig(depth=depth, width=width, seed=seed)
        epochs_end = _CMW_HEADER.size + window * _EPOCH.itemsize
        bucket_size = _CM_HEADER.size + rows * _ROW_COUNT.size + 12 * rows * cfg.cells
        expected = epochs_end + window * bucket_size
        if len(data) != expected:
            # covers payloads cut mid-bucket and mid-label-table alike
            raise ValueError(
                f"count-min window payload is {len(data)} bytes, expected "
                f"{expected} for W={window}, B={rows}, d={depth}, w={width}"
            )
        epochs = np.frombuffer(data[_CMW_HEADER.size : epochs_end], _EPOCH)
        _validate_epoch_ring(epochs.astype(np.int64), cursor, window)
        counters, labels, votes, limbs = [], [], [], []
        for w in range(window):
            start = epochs_end + w * bucket_size
            bucket = CountMinBank.from_bytes(data[start : start + bucket_size])
            if bucket.cfg != cfg or len(bucket) != rows:
                raise ValueError(f"bucket {w} disagrees with the window header")
            counters.append(bucket.counters)
            labels.append(bucket.labels)
            votes.append(bucket.label_counts)
            limbs.append(bucket.n_items)
        return cls(
            jnp.stack(counters),
            jnp.stack(labels),
            jnp.stack(votes),
            jnp.stack(limbs),
            jnp.asarray(cursor, jnp.int32),
            jnp.asarray(epochs.copy()),
            cfg,
        )


# ----------------------------------------------------------------------------
# the batched entry point, roadmap-style
# ----------------------------------------------------------------------------


def cm_update_many(
    bank: CountMinBank,
    keys: jnp.ndarray,
    items: jnp.ndarray,
    plan: Optional[ExecutionPlan] = None,
) -> CountMinBank:
    """Batched heavy-hitter ingestion: one fused dispatch for the bank."""
    return bank.update_many(keys, items, plan)
