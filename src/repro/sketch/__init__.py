"""repro.sketch — the single public API for the paper's HLL engine.

Object API (preferred):

    from repro.sketch import HyperLogLog, HLLConfig, ExecutionPlan

    sk = HyperLogLog.empty(HLLConfig(p=16, hash_bits=64))
    sk = sk.update(items)                                # default jnp plan
    sk = sk.update(items, ExecutionPlan(backend="pallas_pipelined"))
    est = sk.estimate()
    ab = a | b                                           # Merge-buckets fold
    blob = sk.to_bytes(); back = HyperLogLog.from_bytes(blob)

Functional register-level API (for jitted datapaths that carry raw (m,)
arrays in their state pytrees): init_registers / update_registers /
datapath_tap / merge / estimate / estimate_device / estimate_many.

Multi-tenant banks (DESIGN.md §9): ``SketchBank`` stacks B same-config
sketches into one (B, m) pytree and ``update_many(bank, keys, items,
plan)`` routes a keyed stream into the whole bank with one fused
scatter-max — the ingest-side counterpart of ``estimate_many``.  Bank
ingest paths register per backend via ``register_bank_backend`` and are
bit-identical to the per-sketch update loop (tests/test_bank.py).

Windowed cardinality (DESIGN.md §11): ``WindowedBank`` rings W time-bucket
banks into one (W, B, m) pytree — ``observe`` ingests into the current
bucket via the fused bank scatter, ``advance``/``advance_to`` rotate and
expire buckets, and ``estimate_window(last_k)`` answers "distinct per row
over the last k epochs" with ONE masked ring fold (per-backend via
``register_window_backend``) + one batched ``estimate_many``.  Reads are
incrementally maintained (DESIGN.md §14): a hidden prefix/suffix fold
decomposition plus per-instance fold caches make steady-state full-window
queries O(1) in W (merged via ``register_window_merge_backend``),
bit-identical to the cold fold; ``MultiResWindowedBank`` is the
exponential-histogram option for long horizons at O(log horizon) slots.

Heavy hitters (DESIGN.md §13): ``CountMinBank`` stacks B count-min
sketches with Topkapi top-k labels into one (B, d, w) pytree —
``update_many`` ingests a keyed stream with one fused d-hash scatter-add
(per backend via ``register_cm_backend``), ``query`` answers point
frequencies with a fused gather-min, ``topk(k)`` recovers per-row heavy
hitters, and ``WindowedCountMinBank`` rides the same epoch ring with a
fused window SUM-fold (``register_cm_window_backend``).

Estimation (paper phase 4) dispatches through a pluggable registry over the
register-value histogram (repro/sketch/estimators.py, DESIGN.md §8):
``estimator="original" | "ertl_improved" | "ertl_mle"`` on every estimate
entry point, plus ``estimate_many`` to finalize a stacked (B, m) register
bank in one jitted device call.

Every (backend, placement, pipelines) ExecutionPlan produces bit-identical
registers on the same stream — property-tested in tests/test_sketch_api.py.
The legacy surfaces (repro.core.hll, repro.core.sketch, repro.core.setops,
repro.kernels.ops) remain importable as deprecated shims over this package.
See DESIGN.md for the layout and dispatch rules.
"""

from repro.sketch.hll import (  # noqa: F401
    HLLConfig,
    REGISTER_DTYPE,
    alpha,
    cardinality,
    estimate,
    estimate_device,
    hash_index_rank,
    init_registers,
    merge,
    standard_error,
    update,
)
from repro.sketch.plan import (  # noqa: F401
    CMBackend,
    DEFAULT_PIPELINES,
    DEFAULT_PLAN,
    ExecutionPlan,
    SparseDedup,
    available_backends,
    available_bank_backends,
    available_cm_backends,
    available_cm_window_backends,
    available_sparse_backends,
    available_window_backends,
    available_window_merge_backends,
    example_plans,
    get_backend,
    get_bank_backend,
    get_cm_backend,
    get_cm_window_backend,
    get_sparse_backend,
    get_window_backend,
    get_window_merge_backend,
    reference_plan,
    register_backend,
    register_bank_backend,
    register_cm_backend,
    register_cm_window_backend,
    register_sparse_backend,
    register_window_backend,
    register_window_merge_backend,
)

from repro.sketch.estimators import (  # noqa: F401
    DEFAULT_ESTIMATOR,
    Estimator,
    available_estimators,
    estimate_from_histogram,
    estimate_many,
    get_estimator,
    histogram_size,
    register_estimator,
    register_histogram,
    validate_registers,
)

# importing backends registers the built-in "jnp"/"pallas"/"pallas_pipelined"
# entries; it must come after .plan (registry) and .hll (primitives).
from repro.sketch import backends  # noqa: F401  (registration side effect)
from repro.sketch.dispatch import (  # noqa: F401
    datapath_tap,
    dedup_pairs,
    update_registers,
)
from repro.sketch.carrier import HyperLogLog  # noqa: F401
from repro.sketch.bank import (  # noqa: F401
    SketchBank,
    update_bank_registers,
    update_many,
)
from repro.sketch.sparse import HybridBank, default_threshold  # noqa: F401
from repro.sketch.window import (  # noqa: F401
    HybridWindowedBank,
    MultiResWindowedBank,
    WindowedBank,
)
from repro.sketch.countmin import (  # noqa: F401
    CMConfig,
    CountMinBank,
    WindowedCountMinBank,
    cm_hash_index,
    cm_update_many,
    query_cm_counters,
    update_cm_counters,
)
from repro.sketch.setops import (  # noqa: F401
    difference_estimate,
    intersection_estimate,
    jaccard_estimate,
    union_estimate,
)
