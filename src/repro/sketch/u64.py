"""TPU-native 64-bit unsigned arithmetic on uint32 limb pairs.

TPUs have no native 64-bit integer datapath (XLA emulates ``u64`` poorly on
TPU and Pallas/Mosaic rejects it outright), and the VPU exposes no ``umulhi``.
The paper's Murmur3-64 pipeline therefore cannot be ported with ``jnp.uint64``
— instead every 64-bit quantity is carried as a ``(hi, lo)`` pair of uint32
arrays and multiplication is decomposed into 16-bit partial products, all of
which fit a 32-bit lane exactly.  This mirrors how the FPGA design maps the
64-bit multiply onto multiple DSP slices.

All functions are shape-polymorphic and jit/Pallas friendly (pure jnp ops,
no control flow on values).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

MASK32 = np.uint32(0xFFFFFFFF)
MASK16 = np.uint32(0xFFFF)


class U64(NamedTuple):
    """A 64-bit unsigned integer as two uint32 limbs."""

    hi: jnp.ndarray
    lo: jnp.ndarray


def u64(hi: int, lo: int) -> U64:
    """Build a scalar U64 constant from python ints."""
    return U64(np.uint32(hi & 0xFFFFFFFF), np.uint32(lo & 0xFFFFFFFF))


def from_py(value: int) -> U64:
    """Build a scalar U64 constant from a python int < 2**64."""
    value &= (1 << 64) - 1
    return u64(value >> 32, value & 0xFFFFFFFF)


def from_u32(x: jnp.ndarray) -> U64:
    """Zero-extend a uint32 array into a U64."""
    x = x.astype(jnp.uint32)
    return U64(jnp.zeros_like(x), x)


def to_py(x: U64) -> int:
    """Collapse a scalar U64 back to a python int (test helper)."""
    return (int(x.hi) << 32) | int(x.lo)


def xor(a: U64, b: U64) -> U64:
    return U64(a.hi ^ b.hi, a.lo ^ b.lo)


def add(a: U64, b: U64) -> U64:
    """64-bit add modulo 2**64 with carry propagation."""
    lo = (a.lo + b.lo).astype(jnp.uint32)
    carry = (lo < a.lo).astype(jnp.uint32)
    hi = (a.hi + b.hi + carry).astype(jnp.uint32)
    return U64(hi, lo)


def _mul32_full(a: jnp.ndarray, b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full 32x32 -> 64 bit product via 16-bit partial products.

    Every partial product is <= (2^16-1)^2 < 2^32, so each fits uint32
    exactly; the carry chain is assembled explicitly.  Returns (hi, lo).
    """
    a0 = a & MASK16
    a1 = a >> 16
    b0 = b & MASK16
    b1 = b >> 16

    p00 = a0 * b0  # bits [0, 32)
    p01 = a0 * b1  # bits [16, 48)
    p10 = a1 * b0  # bits [16, 48)
    p11 = a1 * b1  # bits [32, 64)

    # middle = p01 + p10 could overflow 32 bits -> track its carry.
    mid = (p01 + p10).astype(jnp.uint32)
    mid_carry = (mid < p01).astype(jnp.uint32)  # 1 iff the 2^32 bit was set

    lo = (p00 + ((mid & MASK16) << 16)).astype(jnp.uint32)
    lo_carry = (lo < p00).astype(jnp.uint32)

    hi = (p11 + (mid >> 16) + (mid_carry << 16) + lo_carry).astype(jnp.uint32)
    return hi, lo


def mul(a: U64, b: U64) -> U64:
    """64-bit multiply modulo 2**64.

    (a.hi*2^32 + a.lo) * (b.hi*2^32 + b.lo) mod 2^64
      = (a.lo*b.lo)  +  ((a.lo*b.hi + a.hi*b.lo) << 32)
    """
    hi, lo = _mul32_full(a.lo, b.lo)
    cross = (a.lo * b.hi + a.hi * b.lo).astype(jnp.uint32)  # mod 2^32 is fine
    return U64((hi + cross).astype(jnp.uint32), lo)


def shr(a: U64, n: int) -> U64:
    """Logical right shift by a static amount 0 < n < 64."""
    if not 0 < n < 64:
        raise ValueError(f"shift must be in (0, 64), got {n}")
    if n < 32:
        lo = (a.lo >> n) | (a.hi << (32 - n))
        hi = a.hi >> n
    elif n == 32:
        lo, hi = a.hi, jnp.zeros_like(a.hi)
    else:
        lo = a.hi >> (n - 32)
        hi = jnp.zeros_like(a.hi)
    return U64(hi.astype(jnp.uint32), lo.astype(jnp.uint32))


def shl(a: U64, n: int) -> U64:
    """Left shift by a static amount 0 < n < 64."""
    if not 0 < n < 64:
        raise ValueError(f"shift must be in (0, 64), got {n}")
    if n < 32:
        hi = (a.hi << n) | (a.lo >> (32 - n))
        lo = a.lo << n
    elif n == 32:
        hi, lo = a.lo, jnp.zeros_like(a.lo)
    else:
        hi = a.lo << (n - 32)
        lo = jnp.zeros_like(a.lo)
    return U64(hi.astype(jnp.uint32), lo.astype(jnp.uint32))


def rotl(a: U64, n: int) -> U64:
    """Rotate left by a static amount 0 < n < 64 (Murmur3's ROTL64)."""
    n %= 64
    if n == 0:
        return a
    left = shl(a, n)
    right = shr(a, 64 - n)
    return U64(left.hi | right.hi, left.lo | right.lo)


def clz32(x: jnp.ndarray) -> jnp.ndarray:
    """Branch-free count-leading-zeros of a uint32 array.

    TPU's VPU has no clz instruction; a 5-step binary search of select ops is
    exact for every input (unlike float-exponent tricks which round above
    2^24).  Returns int32 in [0, 32].
    """
    x = x.astype(jnp.uint32)
    n = jnp.zeros(x.shape, jnp.int32)
    for shift_amount in (16, 8, 4, 2, 1):
        mask_high = x >= jnp.uint32(1 << (32 - shift_amount))
        n = jnp.where(mask_high, n, n + shift_amount)
        x = jnp.where(mask_high, x, x << shift_amount)
    # all-zero input: the loop above counted 31, fix to 32.
    return jnp.where(x == 0, jnp.int32(32), n)


def clz(a: U64) -> jnp.ndarray:
    """Count leading zeros of a U64; int32 in [0, 64]."""
    hi_clz = clz32(a.hi)
    lo_clz = clz32(a.lo)
    return jnp.where(a.hi != 0, hi_clz, 32 + lo_clz)
